"""Async gossip simulator: determinism, partitions, churn, adversaries.

The simulator's contract (see ``repro/chain/sim.py``): same seed ⇒
bit-identical ``SimReport`` and final chains; partitions heal to one
verified chain with zero credit divergence; adversarial payloads are
rejected on the receive-side re-verification paths PR 2 built.
"""
import pytest

from repro.chain import LinkModel, Network, Node, Sim, SimConfig
from repro.chain.sim import (
    PayloadCorrupter, StaleSpammer, WithholdingMiner,
    adversarial_scenario, partitioned_scenario,
)


def _roots(node):
    return [b.merkle_root for b in node.ledger.blocks]


class TestDeterminism:
    def test_seeded_run_bit_reproducible(self):
        """Same seed ⇒ identical SimReport JSON and identical final
        chain (block hashes + roots) — the acceptance criterion."""
        runs = []
        for _ in range(2):
            sim = partitioned_scenario(seed=11)
            report = sim.run()
            tip = sim.honest_nodes[0].ledger.tip_hash
            runs.append((report.to_json(), tip,
                         _roots(sim.honest_nodes[0])))
        assert runs[0] == runs[1]

    def test_different_seed_changes_timings_not_safety(self):
        r5 = partitioned_scenario(seed=5).run()
        r6 = partitioned_scenario(seed=6).run()
        assert r5.to_json() != r6.to_json()      # latency draws differ
        assert r5.converged and r6.converged     # safety never does
        assert r5.credit_divergence == 0.0 == r6.credit_divergence


class TestPartition:
    def test_partition_heals_to_convergence(self):
        """4 nodes split 2|2, the halves mine 2 vs 3 blocks, heal: the
        shorter half reorgs (depth-2) onto the longer chain and every
        credit book is rebuilt to bit-consistency."""
        sim = partitioned_scenario(n_nodes=4, seed=0,
                                   blocks_a=2, blocks_b=3)
        report = sim.run()
        assert report.converged
        assert report.credit_divergence == 0.0
        assert report.canonical_height == 3
        assert report.final_heights == [3, 3, 3, 3]
        # both nodes of the losing half discarded their 2-block fork
        assert report.fork_depth_hist.get(2) == 2
        assert report.orphans == 2
        assert report.orphan_rate == pytest.approx(2 / 5)
        # cross-partition gossip was dropped while split
        assert report.drops_partition > 0
        # the books agree entry-by-entry, not just in aggregate
        books = {tuple(sorted(n.book.balances.items()))
                 for n in sim.honest_nodes}
        assert len(books) == 1

    def test_partition_without_heal_stays_diverged(self):
        sim = partitioned_scenario(n_nodes=4, seed=0)
        # stop before the heal event fires
        report = sim.run(until=3.9)
        assert not report.converged
        assert report.unfinalized > 0

    def test_lossy_links_converge_via_sync(self):
        """Dropped deliveries leave peers behind; the next delivery's
        tip mismatch triggers a chain pull that catches them up."""
        nodes = [Node(node_id=i, classic_arg_bits=6) for i in range(3)]
        sim = Sim(nodes, SimConfig(
            seed=2, link=LinkModel(drop_prob=0.4)))
        for b in range(5):
            sim.mine_at(1.0 + b, 0)
        for nid in range(3):
            sim.announce_at(7.0, nid)
        report = sim.run()
        assert report.drops_random > 0
        assert report.converged
        assert report.final_heights == [5, 5, 5]
        assert report.credit_divergence == 0.0


class TestChurn:
    def test_join_mid_chain_syncs_and_mines(self):
        """A node joining mid-chain pulls a peer's chain through
        consider_chain (ledger + credit book rebuilt from verified
        payloads) and can then mine blocks the network accepts."""
        nodes = [Node(node_id=i, classic_arg_bits=6) for i in range(2)]
        sim = Sim(nodes, SimConfig(seed=4))
        sim.mine_at(1.0, 0)
        sim.mine_at(2.0, 1)
        sim.join_at(3.0, Node(node_id=2, classic_arg_bits=6))
        sim.mine_at(4.0, 2)                      # the joiner mines next
        report = sim.run()
        assert report.joins == 1
        assert report.converged
        assert report.final_heights == [3, 3, 3]
        assert report.credit_divergence == 0.0
        # the joiner's catch-up sync is a depth-0 reorg (pure adoption)
        assert report.fork_depth_hist.get(0, 0) >= 1


class TestAdversaries:
    def test_withholding_release_causes_deep_reorg(self):
        """Selfish mining: the released private chain outruns the honest
        chain, honest nodes reorg (orphaning their own blocks and the
        credits minted on them) and still converge."""
        sim = adversarial_scenario(n_honest=3, seed=0)
        report = sim.run()
        assert report.blocks_withheld == 3
        assert report.converged
        assert report.credit_divergence == 0.0
        # honest nodes discarded their 2-block chain for the private 3
        assert report.fork_depth_hist.get(2, 0) >= 3
        assert report.orphans >= 2
        # the withheld chain's credits all sit in the withholder's lane
        from repro.chain.workload import MINER_LANE
        wid = 3
        book = sim.honest_nodes[0].book
        withheld_credit = sum(a for m, a in book.balances.items()
                              if m // MINER_LANE == wid)
        assert withheld_credit == pytest.approx(3 * 50.0)

    def test_corrupter_never_enters_honest_chains(self):
        """Every outgoing (block, payload) of the corrupter is tampered
        consistently, so rejection happens in the workload's §3 req. 2
        re-verification — and its blocks are orphaned everywhere."""
        sim = adversarial_scenario(n_honest=3, seed=0)
        cid = 4
        report = sim.run()
        assert report.converged
        for node in sim.honest_nodes:
            assert all(p.origin != cid for p in node.chain_payloads())
            assert all(m // 65536 != cid
                       for m in node.book.balances)
        # corrupt deliveries were rejected, then their chain syncs failed
        # on the broken hash links
        assert report.rejects > 0 and report.sync_rejects > 0

    def test_stale_spammer_is_idempotent_noise(self):
        """Rebroadcasting old blocks must change nothing: peers count
        duplicates and never re-commit or re-mint."""
        nodes = [Node(node_id=i, classic_arg_bits=6) for i in range(3)]
        sim = Sim(nodes, SimConfig(seed=7),
                  adversaries={2: StaleSpammer(every=1.0, until=6.0,
                                               height=0)})
        sim.mine_at(0.5, 0)
        sim.mine_at(2.0, 1)
        report = sim.run()
        assert report.spam_sent > 0
        assert report.duplicates >= report.spam_sent
        assert report.final_heights == [2, 2, 2]
        issued = {n.book.total_issued for n in sim.honest_nodes}
        assert issued == {2 * 50.0}


class TestGuards:
    def test_wallclock_difficulty_rejected(self):
        node = Node(classic_arg_bits=6, target_block_s=1.0, work=64)
        with pytest.raises(ValueError, match="bit-reproducibility"):
            Sim([node])

    def test_shared_workload_instance_rejected(self):
        from repro.chain.workload import ClassicSha256Workload
        shared = ClassicSha256Workload(arg_bits=6)
        nodes = [Node(node_id=i, workloads={"classic": shared})
                 for i in range(2)]
        with pytest.raises(ValueError, match="shared"):
            Sim(nodes)

    def test_duplicate_node_id_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            Sim([Node(node_id=0), Node(node_id=0)])

    def test_mesh_with_miner_axes_plus_lanes_rejected_at_construction(self):
        import jax
        import numpy as np
        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        with pytest.raises(ValueError, match="n_lanes"):
            Node(mesh=mesh, n_lanes=2)

    def test_join_unknown_sync_from_raises(self):
        sim = Sim([Node(node_id=0, classic_arg_bits=6)], SimConfig())
        sim.join_at(1.0, Node(node_id=1, classic_arg_bits=6),
                    sync_from=99)
        with pytest.raises(ValueError, match="sync_from"):
            sim.run()

    def test_join_explicit_sync_from_across_partition_is_counted(self):
        """An explicitly requested bootstrap sync over a partitioned
        link must be recorded (drops_partition), not silently skipped."""
        nodes = [Node(node_id=i, classic_arg_bits=6) for i in range(2)]
        sim = Sim(nodes, SimConfig(seed=1))
        sim.mine_at(0.5, 0)
        sim.partition_at(1.0, [[0], [1]])
        sim.join_at(2.0, Node(node_id=2, classic_arg_bits=6),
                    sync_from=0)        # joiner lands in group 0 != node 0
        report = sim.run()
        assert report.joins == 1
        assert report.drops_partition >= 1

    def test_auto_mine_jitter_never_rewinds_time(self):
        """Jitter draws larger than the period must not schedule into
        the past — finality metrics rely on monotonic simulated time."""
        nodes = [Node(node_id=i, classic_arg_bits=6) for i in range(2)]
        sim = Sim(nodes, SimConfig(seed=3))
        sim.auto_mine(0, every=0.3, until=3.0, jitter=1.0)
        report = sim.run()
        assert report.blocks_mined > 1
        assert report.ttf_mean >= 0.0 and report.ttf_max >= 0.0
        assert report.converged

    def test_max_events_backstop_raises(self):
        sim = Sim([Node(node_id=0)], SimConfig(seed=0, max_events=10))

        def loop():
            sim.at(sim.now + 1.0, loop)

        sim.at(0.0, loop)
        with pytest.raises(RuntimeError, match="max_events"):
            sim.run()


class TestMultiLaneMining:
    def test_lane_partitioned_block_verifies_everywhere(self):
        """A Node(n_lanes=4) mines full/optimal blocks in one vmapped
        dispatch whose rewards land in its own lanes; single-lane peers
        verify them bit-exactly (lane partitioning never changes the
        mined bits)."""
        from repro.chain.workload import MINER_LANE
        from repro.core.jash import Jash, JashMeta, collatz_jash

        def small(bits=6):
            base = collatz_jash(max_steps=64)
            return Jash(base.name, base.fn,
                        JashMeta(arg_bits=bits, res_bits=32),
                        example_args=base.example_args)

        net = Network.create(
            2, node_factory=lambda i: Node(
                node_id=i, classic_arg_bits=6,
                n_lanes=4 if i == 0 else 1))
        net.nodes[0].submit(small())
        res = net.mine(0, "full")
        assert not res.rejected_by
        # node 0's lane base is 0, so its global miner ids are 0..3
        assert {m for m, _ in res.receipt.rewards} == {0, 1, 2, 3}
        net.nodes[0].submit(small())
        res = net.mine(0, "optimal")
        assert not res.rejected_by
        winner = res.receipt.record.winner
        assert winner // MINER_LANE == 0 and winner % MINER_LANE < 4
        res = net.mine(0)                        # classic fallback, laned
        assert not res.rejected_by
        assert net.converged()
        books = {tuple(sorted(n.book.balances.items()))
                 for n in net.nodes}
        assert len(books) == 1

    def test_lanes_in_simulator(self):
        """Multi-lane miners inside the async sim: reports stay
        bit-reproducible and chains converge."""
        r1 = partitioned_scenario(seed=9, n_lanes=4).run()
        r2 = partitioned_scenario(seed=9, n_lanes=4).run()
        assert r1.to_json() == r2.to_json()
        assert r1.converged and r1.credit_divergence == 0.0
