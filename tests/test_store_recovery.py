"""Crash-fault tolerance (DESIGN §12): the durable chain journal,
``Node.recover``, finality checkpoints, and the sim's fault injection.

The contracts under test:

* **journal round-trips are bit-exact** for every payload family (full
  evidence arrays, optimal/classic replays, SAT certificates, stateful
  GAN/docking commitments, training-shaped payloads) — encode →
  decode → encode is the identity on bytes, and a decoded header
  re-hashes to the same ``block_hash``;
* **recovery is total**: whatever prefix of the journal survives a
  crash — including a tail torn or bit-flipped at *any* byte — the
  node restarts to a valid (possibly shorter) chain and reconverges
  bit-identically with its peers, never raising;
* **finality is a fence and a budget**: a reorg crossing the finalized
  height is refused no matter how long the rival chain is, and
  finalization prunes snapshots/evidence so retained state is bounded;
* the chaos sim scenario exercises all of it at once, deterministically.
"""
import dataclasses

import jax.numpy as jnp
import pytest

from repro.chain import (ChainError, ChainStore, Network, Node, VerifyCache)
from repro.chain.sim import LinkModel, Sim, SimConfig, chaos_scenario
from repro.chain.store import (decode_block, decode_payload, encode_block,
                               encode_payload)
from repro.chain.workload import BlockPayload
from repro.chain.workloads import default_suite
from repro.core.jash import Jash, JashMeta, collatz_jash

SMALL = dict(sat={"n_vars": 8, "n_clauses": 32},
             gan={"grid_bits": 6},
             docking={"n_r": 8, "n_p": 8})


def small_collatz(arg_bits: int = 6, max_steps: int = 64) -> Jash:
    base = collatz_jash(max_steps=max_steps)
    return Jash(base.name, base.fn,
                JashMeta(arg_bits=arg_bits, res_bits=32, importance=0.9),
                example_args=base.example_args)


def mix_jash(arg_bits: int = 6, salt: int = 0xC0FFEE) -> Jash:
    def fn(a):
        return (a * jnp.uint32(2654435761)) ^ jnp.uint32(salt)
    return Jash(f"mix{salt:x}", fn,
                JashMeta(arg_bits=arg_bits, res_bits=32),
                example_args=(jnp.uint32(0),))


def suite_node(i: int, seed: int = 7, **node_kwargs) -> Node:
    return Node(node_id=i, classic_arg_bits=6,
                workloads=default_suite(seed=seed, **SMALL), **node_kwargs)


def clone(store: ChainStore) -> ChainStore:
    """A recovery always reads a *copy* of the journal bytes, as a
    restarted process reading the disk image would."""
    return ChainStore.from_bytes(store.to_bytes())


# ---------------------------------------------------------------------------
# journal round-trips: every payload family, bit-exact
# ---------------------------------------------------------------------------

FAMILY_SCHEDULE = ("full", "optimal", "sat", "gan", "docking", "classic")


@pytest.fixture(scope="module")
def family_chain():
    """A 2-node network whose journaled node mined one block of every
    family; returns (network, jash_fns for the two queued jashes)."""
    net = Network.create(
        2, node_factory=lambda i: suite_node(
            i, store=ChainStore() if i == 0 else None))
    co, mx = small_collatz(), mix_jash()
    net.nodes[0].submit(co)
    net.nodes[0].submit(mx)
    for family in FAMILY_SCHEDULE:
        res = net.mine(0, family)
        assert not res.rejected_by
    assert net.converged()
    return net, {co.name: co.fn, mx.name: mx.fn}


class TestJournalRoundTrip:
    def test_every_family_bit_exact(self, family_chain):
        net, fns = family_chain
        node = net.nodes[0]
        payloads = node.chain_payloads()
        assert {p.workload for p in payloads} == set(FAMILY_SCHEDULE)
        for blk, payload in zip(node.ledger.blocks, payloads):
            pe = encode_payload(payload)
            decoded = decode_payload(pe, jash_fns=fns)
            assert encode_payload(decoded) == pe
            be = encode_block(blk)
            blk2 = decode_block(be)
            assert encode_block(blk2) == be
            # the header hash is timestamp-free by design, so a decoded
            # header re-hashes to the identical chain commitment
            assert blk2.block_hash == blk.block_hash

    def test_sat_certificate_survives(self, family_chain):
        net, fns = family_chain
        sat = next(p for p in net.nodes[0].chain_payloads()
                   if p.workload == "sat")
        assert sat.certificate            # the family's defining evidence
        decoded = decode_payload(encode_payload(sat), jash_fns=fns)
        assert decoded.certificate == sat.certificate

    def test_training_shaped_payload(self):
        payload = BlockPayload(
            workload="training", jash_id="t" * 64, merkle_root="m" * 64,
            n_results=1, winner=2, state_digest="s" * 64, origin=1,
            block_reward=9.5, loss=0.125, train_height=3, n_miners=2)
        decoded = decode_payload(encode_payload(payload))
        assert encode_payload(decoded) == encode_payload(payload)
        assert decoded == payload

    def test_garbage_bytes_raise_chain_error(self):
        with pytest.raises(ChainError):
            decode_payload(b"not a journal body")
        with pytest.raises(ChainError):
            decode_block(b"\x00" * 8)

    def test_read_chain_never_raises_on_garbage(self):
        read = ChainStore.from_bytes(b"garbage" * 16).read_chain()
        assert not read.clean and read.blocks == []


# ---------------------------------------------------------------------------
# restart recovery
# ---------------------------------------------------------------------------

class TestRecover:
    def test_classic_tip_byte_identical(self):
        donor = Node(node_id=0, classic_arg_bits=5, store=ChainStore())
        for _ in range(5):
            donor.mine_block()
        node = Node.recover(clone(donor.store),
                            node=Node(node_id=0, classic_arg_bits=5))
        rec = node.last_recovery
        assert (rec.replayed, rec.adopted_height,
                rec.truncated_records) == (5, 5, 0)
        assert (encode_block(node.ledger.blocks[-1])
                == encode_block(donor.ledger.blocks[-1]))
        assert node.book.balances == donor.book.balances

    def test_suite_chain_recovers_with_stateful_replay(self, family_chain):
        net, fns = family_chain
        donor = net.nodes[0]
        node = Node.recover(clone(donor.store), node=suite_node(0),
                            jash_fns=fns)
        assert node.ledger.tip_hash == donor.ledger.tip_hash
        assert node.book.balances == donor.book.balances
        # replaying the journal advanced the stateful families to the
        # same committed state the donor reached by mining
        assert (node.workloads["gan"].state_digest()
                == donor.workloads["gan"].state_digest())

    def test_torn_suite_tail_truncates_then_peer_resync(self, family_chain):
        net, fns = family_chain
        donor = net.nodes[0]
        damaged = clone(donor.store)
        damaged.truncate_bytes(damaged.size - 9)
        node = Node.recover(damaged, peers=[donor], node=suite_node(0),
                            jash_fns=fns)
        rec = node.last_recovery
        assert rec.truncated_records == 1
        assert rec.adopted_height == donor.ledger.height - 1
        assert rec.resynced_height == donor.ledger.height
        assert node.ledger.tip_hash == donor.ledger.tip_hash

    def test_fork_choice_journals_the_truncate(self):
        a = Node(node_id=0, classic_arg_bits=5, store=ChainStore())
        a.submit(mix_jash(arg_bits=5))
        a.mine_block("optimal")           # diverges from B at height 0
        a.mine_block()
        b = Node(node_id=1, classic_arg_bits=5)
        for _ in range(3):
            b.mine_block()
        assert a.consider_chain(b.ledger.blocks, b.chain_payloads())
        # journal = 2 commits + TRUNCATE(0) + 3 commits, folding to B's
        # chain — a recovery replays straight to the post-reorg tip
        read = a.store.read_chain()
        assert read.clean and len(read.blocks) == 3
        node = Node.recover(clone(a.store),
                            node=Node(node_id=0, classic_arg_bits=5))
        assert node.ledger.tip_hash == b.ledger.tip_hash

    def test_shell_and_store_preconditions(self):
        donor = Node(node_id=0, classic_arg_bits=4, store=ChainStore())
        donor.mine_block()
        with pytest.raises(ValueError):      # used journal needs recover()
            Node(node_id=1, classic_arg_bits=4, store=clone(donor.store))
        mined = Node(node_id=1, classic_arg_bits=4)
        mined.mine_block()
        with pytest.raises(ChainError):      # shell must be empty
            Node.recover(clone(donor.store), node=mined)
        with pytest.raises(ChainError):      # shell must be storeless
            Node.recover(clone(donor.store),
                         node=Node(node_id=1, classic_arg_bits=4,
                                   store=ChainStore()))


# ---------------------------------------------------------------------------
# torn-write property sweep: damage at every byte of the last record
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def torn_donor():
    donor = Node(node_id=0, classic_arg_bits=4, store=ChainStore())
    for _ in range(3):
        donor.mine_block()
    start, end = donor.store._record_spans()[-1]
    return donor, donor.store.to_bytes(), start, end


class TestTornWrites:
    def test_truncation_at_every_byte_boundary(self, torn_donor):
        donor, base, start, end = torn_donor
        for cut in range(start, end):
            node = Node.recover(ChainStore.from_bytes(base[:cut]),
                                peers=[donor],
                                node=Node(node_id=0, classic_arg_bits=4))
            rec = node.last_recovery
            assert rec.adopted_height == 2   # the torn record is lost
            assert rec.resynced_height == 3
            assert (encode_block(node.ledger.blocks[-1])
                    == encode_block(donor.ledger.blocks[-1]))
            assert node.book.balances == donor.book.balances

    def test_bitflip_at_every_byte(self, torn_donor):
        donor, base, start, end = torn_donor
        for off in range(start, end):
            store = ChainStore.from_bytes(base)
            store.flip_bit(off)
            node = Node.recover(store, peers=[donor],
                                node=Node(node_id=0, classic_arg_bits=4))
            assert node.last_recovery.truncated_records >= 1
            assert node.last_recovery.resynced_height == 3
            assert (encode_block(node.ledger.blocks[-1])
                    == encode_block(donor.ledger.blocks[-1]))

    def test_damaged_journal_is_compacted_on_recovery(self, torn_donor):
        donor, base, start, end = torn_donor
        store = ChainStore.from_bytes(base[:end - 5])
        node = Node.recover(store, peers=[donor],
                            node=Node(node_id=0, classic_arg_bits=4))
        # the rewritten journal now replays cleanly to the synced tip
        read = store.read_chain()
        assert read.clean and len(read.blocks) == node.ledger.height == 3


# ---------------------------------------------------------------------------
# finality: fence, pruning, validation
# ---------------------------------------------------------------------------

class TestFinality:
    def test_constructor_validation(self):
        with pytest.raises(ValueError):
            Node(confirmation_depth=0)
        with pytest.raises(ValueError):      # ring can't cover the fence
            Node(confirmation_depth=50, snapshot_interval=4,
                 snapshot_ring=4)

    def test_consider_chain_input_validation(self):
        node = Node(classic_arg_bits=4)
        donor = Node(node_id=1, classic_arg_bits=4)
        donor.mine_block()
        donor.mine_block()
        with pytest.raises(ChainError):
            node.consider_chain([], [])
        with pytest.raises(ChainError):
            node.consider_chain(donor.ledger.blocks,
                                donor.chain_payloads()[:1])

    def test_fence_rejects_long_range_rewrite(self):
        def finalized_node(depth):
            node = Node(node_id=0, classic_arg_bits=5,
                        confirmation_depth=depth, snapshot_interval=2,
                        snapshot_ring=4)
            node.submit(mix_jash(arg_bits=5))
            node.mine_block("optimal")   # diverge from rival at height 0
            for _ in range(7):
                node.mine_block()
            return node

        rival = Node(node_id=1, classic_arg_bits=5)
        for _ in range(10):
            rival.mine_block()

        node = finalized_node(depth=2)
        assert node.finalized_height == 6
        assert not node.consider_chain(rival.ledger.blocks,
                                       rival.chain_payloads())
        assert node.finality_rejects == 1
        assert node.ledger.height == 8      # kept its own chain
        # without finality the same (longer, valid) rewrite is adopted —
        # the fence, not verification, is what refused it above
        control = Node(node_id=0, classic_arg_bits=5)
        control.submit(mix_jash(arg_bits=5))
        control.mine_block("optimal")
        for _ in range(7):
            control.mine_block()
        assert control.consider_chain(rival.ledger.blocks,
                                      rival.chain_payloads())

    def test_finalization_prunes_evidence_and_snapshots(self):
        node = Node(node_id=0, classic_arg_bits=4, confirmation_depth=2,
                    snapshot_interval=2, snapshot_ring=3)
        for _ in range(10):
            node.mine_block()
        assert node.finalized_height == 8
        floor = node._evidence_floor
        assert 0 < floor <= node.finalized_height
        payloads = node.chain_payloads()
        assert all(p is None for p in payloads[:floor])
        assert all(p is not None for p in payloads[floor:])
        assert len(node._snapshots) <= 3
        assert node.audit_chain()           # audits the retained range

    def test_peer_sync_across_pruned_prefix(self):
        """A pruned peer serves ``None`` payloads below its evidence
        floor; a peer sharing the finalized prefix substitutes its own
        retained evidence below the fork point and still adopts."""
        miner = Node(node_id=0, classic_arg_bits=4, confirmation_depth=2,
                     snapshot_interval=2, snapshot_ring=3)
        follower = Node(node_id=1, classic_arg_bits=4)
        for i in range(10):
            receipt = miner.mine_block()
            if i < 9:                        # follower misses the tip
                assert follower.receive(receipt.record.to_block(),
                                        receipt.payload, origin=0)
        assert miner._evidence_floor > 0
        assert follower.consider_chain(miner.ledger.blocks,
                                       miner.chain_payloads())
        assert follower.ledger.tip_hash == miner.ledger.tip_hash


# ---------------------------------------------------------------------------
# finality-aware VerifyCache eviction
# ---------------------------------------------------------------------------

def _payload(tag: str) -> BlockPayload:
    return BlockPayload(workload="classic", jash_id=tag, merkle_root=tag,
                        n_results=1)


class TestVerifyCacheFinality:
    def test_finalized_entries_evicted_first(self):
        cache = VerifyCache(maxsize=2)
        p1, p2, p3 = _payload("a"), _payload("b"), _payload("c")
        cache.add("h1", p1, height=1)
        cache.add("h2", p2, height=2)
        cache.note_finalized(1)
        cache.add("h3", p3, height=3)       # evicts finalized h1, not h2
        assert cache.evictions == 1
        assert cache.check("h2", p2) and cache.check("h3", p3)
        assert not cache.check("h1", p1)

    def test_fifo_fallback_without_heights(self):
        cache = VerifyCache(maxsize=2)
        p1, p2, p3 = _payload("a"), _payload("b"), _payload("c")
        cache.add("h1", p1)
        cache.add("h2", p2)
        cache.add("h3", p3)                 # no finality info: plain FIFO
        assert cache.evictions == 1
        assert not cache.check("h1", p1)
        assert cache.check("h2", p2)


# ---------------------------------------------------------------------------
# sim fault injection
# ---------------------------------------------------------------------------

class TestSimFaults:
    def test_lossy_links_count_retries(self):
        nodes = [Node(node_id=i, classic_arg_bits=6) for i in range(3)]
        sim = Sim(nodes, SimConfig(
            seed=9, link=LinkModel(drop_prob=0.5, max_retries=2)))
        for b in range(4):
            sim.mine_at(1.0 + b, 0)
        for nid in range(3):
            sim.announce_at(6.0, nid)
        report = sim.run()
        assert report.retries > 0           # drops now get a second try
        assert report.converged
        assert report.final_heights == [4, 4, 4]

    def test_chaos_scenario_acceptance(self):
        report = chaos_scenario(n_nodes=8, seed=1, n_blocks=12).run()
        assert report.converged
        assert report.credit_divergence == 0.0
        assert report.finalized_divergence == 0
        assert len(set(report.finalized_heights)) == 1
        assert report.crashes == 2 and report.recoveries == 2
        assert report.corruptions == 1
        assert report.truncated_records >= 1
        assert report.finality_rejects > 0  # the rewrite hit the fence

    def test_chaos_scenario_bit_reproducible(self):
        rep1 = chaos_scenario(n_nodes=6, seed=3, n_blocks=10).run()
        rep2 = chaos_scenario(n_nodes=6, seed=3, n_blocks=10).run()
        assert rep1.to_json() == rep2.to_json()
        assert rep1.converged and rep1.finalized_divergence == 0
