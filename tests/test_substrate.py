"""Substrate tests: data pipeline determinism, optimizer, schedule,
sharding rules, attention banded/masked equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.configs.base import InputShape
from repro.data.pipeline import SyntheticTokenPipeline
from repro.models.attention import chunked_attention
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.sharding.partition import (batch_specs, cache_specs, constrain,
                                      param_specs)


class TestPipeline:
    def test_deterministic_across_instances(self):
        cfg = reduced(get_config("qwen3-0.6b"))
        shape = InputShape("t", 64, 4, "train")
        a = SyntheticTokenPipeline(cfg, shape, seed=5)
        b = SyntheticTokenPipeline(cfg, shape, seed=5)
        for step in (0, 3, 17):
            np.testing.assert_array_equal(
                np.asarray(a.batch(step)["tokens"]),
                np.asarray(b.batch(step)["tokens"]))
        assert a.checksum() == b.checksum()

    def test_different_seed_different_data(self):
        cfg = reduced(get_config("qwen3-0.6b"))
        shape = InputShape("t", 64, 4, "train")
        a = SyntheticTokenPipeline(cfg, shape, seed=0)
        b = SyntheticTokenPipeline(cfg, shape, seed=1)
        assert not np.array_equal(np.asarray(a.batch(0)["tokens"]),
                                  np.asarray(b.batch(0)["tokens"]))
        assert a.checksum() != b.checksum()

    def test_tokens_in_vocab(self):
        cfg = reduced(get_config("whisper-medium"))
        shape = InputShape("t", 128, 2, "train")
        t = np.asarray(SyntheticTokenPipeline(cfg, shape).batch(0)["tokens"])
        assert t.min() >= 0 and t.max() < cfg.vocab_size


class TestAdamW:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw_init(params)
        target = jnp.asarray([1.0, 2.0])

        @jax.jit
        def step(p, s):
            g = jax.grad(lambda q: jnp.sum((q["w"] - target) ** 2))(p)
            return adamw_update(p, g, s, 0.1, weight_decay=0.0)

        for _ in range(200):
            params, state = step(params, state)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=1e-2)

    def test_grad_clip_bounds_update(self):
        params = {"w": jnp.zeros(3)}
        state = adamw_init(params)
        huge = {"w": jnp.full(3, 1e9)}
        p2, _ = adamw_update(params, huge, state, lr=1.0, grad_clip=1.0,
                             weight_decay=0.0)
        assert np.all(np.abs(np.asarray(p2["w"])) < 10.0)

    def test_step_counter(self):
        params = {"w": jnp.zeros(2)}
        state = adamw_init(params)
        _, s1 = adamw_update(params, params, state, 0.1)
        assert int(s1.step) == 1


class TestSchedule:
    @given(st.integers(0, 10_000))
    @settings(max_examples=50, deadline=None)
    def test_bounded_and_positive(self, step):
        lr = float(cosine_schedule(step, peak_lr=1e-3, warmup_steps=100,
                                   total_steps=10_000))
        assert 0.0 <= lr <= 1e-3 * (1 + 1e-6)   # f32 repr of peak_lr

    def test_warmup_then_decay(self):
        lrs = [float(cosine_schedule(s, peak_lr=1.0, warmup_steps=10,
                                     total_steps=100)) for s in range(100)]
        assert lrs[5] < lrs[9]                    # warming up
        assert lrs[99] < lrs[20]                  # decayed


class TestShardingRules:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_param_specs_cover_big_matrices(self):
        cfg = reduced(get_config("olmoe-1b-7b"))
        from repro.models.model import build_model
        params = jax.eval_shape(
            lambda: build_model(cfg).init(jax.random.key(0)))
        mesh = self._mesh()
        specs = param_specs(params, mesh)
        flat, _ = jax.tree_util.tree_flatten_with_path(specs)
        # every spec has rank <= its param rank
        pflat, _ = jax.tree_util.tree_flatten_with_path(params)
        for (pa, sp), (pb, pv) in zip(flat, pflat):
            assert len(sp) <= len(pv.shape)

    def test_divisibility_fallback_replicates(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # mesh size 1 divides everything; use a fake 16-way check instead
        from repro.sharding.partition import _spec_for
        big = jax.make_mesh((1, 1), ("data", "model"))
        spec = _spec_for("whisper/pos_table", (1500, 64), big, True)
        assert isinstance(spec, P)

    def test_constrain_noop_outside_mesh(self):
        x = jnp.ones((4, 4))
        y = constrain(x, "batch", "tensor")
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_batch_specs_shard_batch_dim(self):
        mesh = self._mesh()
        batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
        specs = batch_specs(batch, mesh, 8)
        assert specs["tokens"] == P(("data",))

    def test_cache_specs_never_shard_ring_dim(self):
        cfg = reduced(get_config("qwen3-0.6b"))
        from repro.models.model import build_model
        model = build_model(cfg)
        cache = jax.eval_shape(lambda: model.init_cache(8, 64))
        mesh = self._mesh()
        specs = cache_specs(cache, mesh, 8)
        flat, _ = jax.tree_util.tree_flatten_with_path(specs)
        for path, sp in flat:
            key = "/".join(str(getattr(k, "key", k)) for k in path)
            if key.endswith("slot_pos"):
                assert sp == P(*([None] * len(sp))) or sp == P()


class TestBandedAttention:
    @pytest.mark.parametrize("S,T,window", [(64, 64, 16), (128, 128, 32)])
    def test_banded_equals_masked(self, S, T, window):
        """The banded (dynamic-slice) path == the full masked path."""
        B, H, Kv, hd = 1, 2, 2, 8
        rs = np.random.RandomState(0)
        q = jnp.asarray(rs.normal(size=(B, S, H, hd)).astype(np.float32))
        k = jnp.asarray(rs.normal(size=(B, T, Kv, hd)).astype(np.float32))
        v = jnp.asarray(rs.normal(size=(B, T, Kv, hd)).astype(np.float32))
        # banded triggers when T > window + chunk
        banded = chunked_attention(q, k, v, causal=True, window=window,
                                   chunk=16)
        masked = chunked_attention(q, k, v, causal=True, window=window,
                                   chunk=S)     # chunk == S -> masked path
        np.testing.assert_allclose(np.asarray(banded), np.asarray(masked),
                                   rtol=1e-4, atol=1e-5)

    def test_window_limits_context(self):
        """A token outside the window must not influence the output."""
        B, S, H, hd = 1, 32, 1, 4
        rs = np.random.RandomState(1)
        q = jnp.asarray(rs.normal(size=(B, S, H, hd)).astype(np.float32))
        k = jnp.asarray(rs.normal(size=(B, S, H, hd)).astype(np.float32))
        v = jnp.asarray(rs.normal(size=(B, S, H, hd)).astype(np.float32))
        out1 = chunked_attention(q, k, v, causal=True, window=4, chunk=8)
        k2 = k.at[:, 0].set(99.0)               # outside window of t>=4
        v2 = v.at[:, 0].set(99.0)
        out2 = chunked_attention(q, k2, v2, causal=True, window=4, chunk=8)
        np.testing.assert_allclose(np.asarray(out1[:, 8:]),
                                   np.asarray(out2[:, 8:]), rtol=1e-5)
