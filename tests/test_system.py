"""End-to-end behaviour tests: the full PNPCoin loop from researcher
submission to rewarded, verified, chained blocks — and training-as-mining
actually learning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import InputShape
from repro.core.authority import RuntimeAuthority
from repro.core.executor import run_full, run_optimal
from repro.core.jash import Jash, JashMeta, collatz_jash
from repro.core.ledger import Ledger, merkle_root
from repro.core.pow_train import PoUWTrainer
from repro.core.rewards import CreditBook, reward_full
from repro.core.verify import quorum_verify
from repro.train.steps import TrainHparams


def test_full_pnpcoin_loop():
    """Researcher -> RA review -> publication -> mining -> verification
    -> ledger -> rewards: the complete Fig. 1 pipeline."""
    ra = RuntimeAuthority()
    ledger = Ledger()
    book = CreditBook()

    ra.submit(collatz_jash(max_steps=256))
    for block_i in range(3):
        jash, src = ra.publish_next()
        if src == "classic":
            jash = Jash(jash.name, jash.fn,
                        JashMeta(arg_bits=5, res_bits=256),
                        example_args=jash.example_args)
        else:
            jash = Jash(jash.name, jash.fn,
                        JashMeta(arg_bits=5, res_bits=32),
                        example_args=jash.example_args)
        full = run_full(jash)
        assert quorum_verify(jash, full, fraction=0.3).ok
        root = merkle_root(full.merkle_leaves)
        ledger.append(jash_id=jash.source_id(), mode="full", merkle=root,
                      winner=None, best_res=None,
                      n_results=len(full.args))
        reward_full(book, full.miner_of.tolist(), 50.0)

    assert ledger.verify_chain()
    assert ledger.height == 3
    assert np.isclose(book.total_issued, 150.0)


def test_training_as_mining_learns():
    """A few dozen blocks of PoUW training must reduce the loss — the
    paper's 'Deep Net training' payload does useful work."""
    cfg = reduced(get_config("qwen3-0.6b"))
    shape = InputShape("t", 64, 8, "train")
    tr = PoUWTrainer(cfg, shape,
                     hp=TrainHparams(peak_lr=2e-3, warmup_steps=5,
                                     total_steps=80),
                     mode="full", n_miners=4)
    recs = tr.run(40)
    first = np.mean([r.loss for r in recs[:5]])
    last = np.mean([r.loss for r in recs[-5:]])
    assert last < first - 0.15, (first, last)
    assert tr.ledger.verify_chain()


def test_optimal_mode_improves_over_random():
    """ES mining should (slightly) reduce loss vs the init params."""
    cfg = reduced(get_config("qwen3-0.6b"))
    shape = InputShape("t", 32, 4, "train")
    tr = PoUWTrainer(cfg, shape, mode="optimal", pop_size=8, sigma=0.01,
                     seed=1)
    base = float(tr._eval_step(tr.state.params, tr.pipeline.batch(0)))
    tr.run(6)
    final = float(tr._eval_step(tr.state.params, tr.pipeline.batch(0)))
    # hillclimb selects per-block batches, so allow modest drift on batch 0
    assert final <= base + 0.3, (base, final)
    # and the per-block accepted loss is the population minimum by
    # construction — chain must be intact
    assert tr.ledger.verify_chain()


def test_docking_use_case_end_to_end():
    """§4: map pair space -> full mode -> aggregate binding results."""
    N_R, N_P = 8, 4

    def matcher(b):
        r, p = b % jnp.uint32(N_R), b // jnp.uint32(N_R)
        score = (r * jnp.uint32(2654435761) ^ p * jnp.uint32(40503)) \
            % jnp.uint32(1000)
        return jnp.where(score < 250, jnp.uint32(0b01), jnp.uint32(0b00))

    jash = Jash("dock", matcher,
                JashMeta(arg_bits=5, res_bits=2, max_arg=N_R * N_P,
                         data_checksum="ab" * 32, importance=0.9),
                example_args=(jnp.uint32(0),))
    ra = RuntimeAuthority()
    ra.submit(jash)
    pub, _ = ra.publish_next()
    full = run_full(pub)
    binds = int((full.results[:, 0] == 1).sum())
    assert 0 < binds < N_R * N_P
    assert quorum_verify(pub, full, fraction=1.0).ok
