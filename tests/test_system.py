"""End-to-end behaviour tests: the full PNPCoin loop from researcher
submission to rewarded, verified, chained blocks — driven through the
``repro.chain`` API — and training-as-mining actually learning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.chain import Node, TrainingWorkload
from repro.configs import get_config, reduced
from repro.configs.base import InputShape
from repro.core.jash import Jash, JashMeta, collatz_jash
from repro.core.pow_train import PoUWTrainer
from repro.train.steps import TrainHparams


def test_full_pnpcoin_loop():
    """Researcher -> RA review -> publication -> mining -> verification
    -> ledger -> rewards: the complete Fig. 1 pipeline, one facade."""
    node = Node(classic_arg_bits=5)
    base = collatz_jash(max_steps=256)
    node.submit(Jash(base.name, base.fn,
                     JashMeta(arg_bits=5, res_bits=32),
                     example_args=base.example_args))

    receipts = [node.mine_block() for _ in range(3)]
    assert [r.record.workload for r in receipts] == \
        ["full", "classic", "classic"]

    s = node.state()
    assert s.chain_valid and s.height == 3
    assert np.isclose(s.total_issued, 150.0)
    assert np.isclose(sum(s.balances.values()), s.total_issued)
    assert all(node.audit(h) for h in range(3))


def test_training_as_mining_learns():
    """A few dozen blocks of PoUW training must reduce the loss — the
    paper's 'Deep Net training' payload does useful work."""
    cfg = reduced(get_config("qwen3-0.6b"))
    shape = InputShape("t", 64, 8, "train")
    node = Node(workloads={"training": TrainingWorkload(
        lambda: PoUWTrainer(cfg, shape,
                            hp=TrainHparams(peak_lr=2e-3, warmup_steps=5,
                                            total_steps=80),
                            mode="full", n_miners=4))})
    receipts = [node.mine_block("training") for _ in range(40)]
    losses = [r.payload.loss for r in receipts]
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.15, (first, last)
    assert node.state().chain_valid
    assert node.audit(39)           # replay audit on the latest block


def test_optimal_mode_improves_over_random():
    """ES mining should (slightly) reduce loss vs the init params —
    kernel-layer coverage of the PoUWTrainer under the chain facade."""
    cfg = reduced(get_config("qwen3-0.6b"))
    shape = InputShape("t", 32, 4, "train")
    tr = PoUWTrainer(cfg, shape, mode="optimal", pop_size=8, sigma=0.01,
                     seed=1)
    base = float(tr._eval_step(tr.state.params, tr.pipeline.batch(0)))
    tr.run(6)
    final = float(tr._eval_step(tr.state.params, tr.pipeline.batch(0)))
    # hillclimb selects per-block batches, so allow modest drift on batch 0
    assert final <= base + 0.3, (base, final)
    # and the per-block accepted loss is the population minimum by
    # construction — chain must be intact
    assert tr.ledger.verify_chain()


def test_docking_use_case_end_to_end():
    """§4: map pair space -> full mode -> aggregate binding results."""
    N_R, N_P = 8, 4

    def matcher(b):
        r, p = b % jnp.uint32(N_R), b // jnp.uint32(N_R)
        score = (r * jnp.uint32(2654435761) ^ p * jnp.uint32(40503)) \
            % jnp.uint32(1000)
        return jnp.where(score < 250, jnp.uint32(0b01), jnp.uint32(0b00))

    jash = Jash("dock", matcher,
                JashMeta(arg_bits=5, res_bits=2, max_arg=N_R * N_P,
                         data_checksum="ab" * 32, importance=0.9),
                example_args=(jnp.uint32(0),))
    node = Node()
    node.submit(jash)
    receipt = node.mine_block()     # default policy: queued jash -> full
    assert receipt.record.workload == "full"
    full = receipt.payload.full
    binds = int((full.results[:, 0] == 1).sum())
    assert 0 < binds < N_R * N_P
    assert node.audit(0)            # quorum re-execution + root recompute
