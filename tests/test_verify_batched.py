"""Batched verification pipeline (DESIGN §10): consensus identity.

The contract under test everywhere here: the batched/incremental paths
— ``quorum_verify_batched``, ``recompute_roots_batched``,
``verify_chain_batched``, fork-point-incremental ``consider_chain``
and the shared ``VerifyCache`` — make exactly the accept/reject
decisions of the per-block, genesis-replay reference, on the same
inputs, including corrupted payloads and stateful (training) rollback.
"""
import dataclasses

import jax.numpy as jnp
import pytest

from repro.chain import Network, Node, verify_chain_batched
from repro.chain.sim import (adversarial_scenario, partitioned_scenario,
                             throughput_scenario)
from repro.chain.workload import (
    BlockContext, BlockPayload, ClassicSha256Workload, JashFullWorkload,
    JashOptimalWorkload,
)
from repro.core.executor import run_full
from repro.core.jash import Jash, JashMeta, collatz_jash
from repro.core.verify import (quorum_verify, quorum_verify_batched,
                               recompute_roots_batched)
from repro.core.ledger import merkle_root


def small_collatz(arg_bits: int = 6, max_steps: int = 64) -> Jash:
    base = collatz_jash(max_steps=max_steps)
    return Jash(base.name, base.fn,
                JashMeta(arg_bits=arg_bits, res_bits=32, importance=0.9),
                example_args=base.example_args)


def mix_jash(arg_bits: int = 6, salt: int = 0xDEADBEEF) -> Jash:
    def fn(a):
        return (a * jnp.uint32(2654435761)) ^ jnp.uint32(salt)
    return Jash(f"mix{salt:x}", fn,
                JashMeta(arg_bits=arg_bits, res_bits=32),
                example_args=(jnp.uint32(0),))


def full_payload(jash: Jash) -> BlockPayload:
    fr = run_full(jash)
    return BlockPayload(workload="full", jash_id=jash.source_id(),
                        merkle_root=fr.commit_root(),
                        n_results=len(fr.args), jash=jash, full=fr)


def corrupt_results(payload: BlockPayload) -> BlockPayload:
    bad = payload.full.results.copy()
    bad[0, 0] ^= 1
    return dataclasses.replace(
        payload, full=dataclasses.replace(payload.full, results=bad))


# ---------------------------------------------------------------------------
# core layer: batched primitives == scalar reference
# ---------------------------------------------------------------------------


class TestQuorumBatched:
    def test_reports_bit_identical_to_scalar(self):
        j1, j2 = mix_jash(6, 1), mix_jash(6, 2)
        f1, f2 = run_full(j1), run_full(j2)
        pairs = [(j1, f1), (j2, f2), (j1, f1)]
        assert quorum_verify_batched(pairs, fraction=0.5) == \
            [quorum_verify(j, f, fraction=0.5) for j, f in pairs]

    def test_corrupted_block_fails_identically(self):
        j = mix_jash(6, 3)
        f = run_full(j)
        bad = dataclasses.replace(f, results=f.results ^ 1)
        batched = quorum_verify_batched([(j, f), (j, bad)], fraction=1.0)
        scalar = [quorum_verify(j, f, fraction=1.0),
                  quorum_verify(j, bad, fraction=1.0)]
        assert batched == scalar
        assert batched[0].ok and not batched[1].ok
        assert batched[1].mismatched_args == scalar[1].mismatched_args

    def test_empty_segment(self):
        assert quorum_verify_batched([]) == []


class TestRootsBatched:
    def test_roots_match_hashlib_reference(self):
        fulls = [run_full(mix_jash(b, 4)) for b in (5, 6, 5)]
        assert recompute_roots_batched(fulls) == \
            [merkle_root(list(f.merkle_leaves), backend="hashlib")
             for f in fulls]

    def test_tampered_results_change_root(self):
        f = run_full(mix_jash(6, 5))
        bad = dataclasses.replace(f, results=f.results ^ 1)
        good_root, bad_root = recompute_roots_batched([f, bad])
        assert good_root != bad_root
        assert good_root == merkle_root(list(f.merkle_leaves),
                                        backend="hashlib")

    def test_device_mismatch_falls_back_to_hashlib(self, monkeypatch):
        """A broken device reducer is caught by the per-shape-group
        hashlib spot-check, and every root then comes from the
        reference path — accept/reject never depends on the kernel."""
        import repro.core.verify as verify_mod
        fulls = [run_full(mix_jash(5, 6)), run_full(mix_jash(6, 7))]
        monkeypatch.setattr(
            verify_mod, "merkle_roots_from_digests",
            lambda d: ["00" * 32] * d.shape[0])
        assert verify_mod.recompute_roots_batched(fulls) == \
            [merkle_root(list(f.merkle_leaves), backend="hashlib")
             for f in fulls]


# ---------------------------------------------------------------------------
# workload layer: verify_batch / verify_chain_batched == wl.verify loop
# ---------------------------------------------------------------------------


class TestVerifyChainBatched:
    def _segment(self):
        j = small_collatz()
        workloads = {"full": JashFullWorkload(),
                     "optimal": JashOptimalWorkload(),
                     "classic": ClassicSha256Workload(arg_bits=6)}
        fp = full_payload(j)
        cw = workloads["classic"]
        cp = cw.mine(cw.prepare(BlockContext(height=0, prev_hash="")))
        ow = workloads["optimal"]
        op = ow.mine(ow.prepare(BlockContext(height=0, prev_hash="",
                                             jash=small_collatz(5))))
        return workloads, [fp, cp, op, fp, cp]

    def test_clean_segment_matches_loop(self):
        workloads, payloads = self._segment()
        assert all(workloads[p.workload].verify(p) for p in payloads)
        assert verify_chain_batched(workloads, payloads)

    @pytest.mark.parametrize("tamper", [
        lambda p: corrupt_results(p),                        # bad results
        lambda p: dataclasses.replace(p, merkle_root="0" * 64),
        lambda p: dataclasses.replace(p, jash_id="deadbeef" * 2),
    ])
    def test_tampered_full_block_rejected_like_loop(self, tamper):
        workloads, payloads = self._segment()
        payloads[3] = tamper(payloads[3])
        assert not workloads["full"].verify(payloads[3])
        assert not verify_chain_batched(workloads, payloads)

    def test_tampered_optimal_block_rejected_like_loop(self):
        workloads, payloads = self._segment()
        payloads[2] = dataclasses.replace(payloads[2], best_arg=1,
                                          best_res="00" * 4)
        assert not workloads["optimal"].verify(payloads[2])
        assert not verify_chain_batched(workloads, payloads)

    def test_unknown_workload_rejected(self):
        workloads, payloads = self._segment()
        payloads[1] = dataclasses.replace(payloads[1], workload="espresso")
        assert not verify_chain_batched(workloads, payloads)

    def test_replay_dedup_is_per_arg_space(self):
        """Two classic payloads over different nonce spaces must not
        share a replay (the dedup key includes n_args)."""
        wl = ClassicSha256Workload(arg_bits=5)
        p5 = wl.mine(wl.prepare(BlockContext(height=0, prev_hash="")))
        wl6 = ClassicSha256Workload(arg_bits=6)
        p6 = wl6.mine(wl6.prepare(BlockContext(height=0, prev_hash="")))
        assert wl.verify_batch([p5, p6]) == [wl.verify(p5), wl.verify(p6)]

    def test_full_content_dedup_parity(self):
        """Byte-identical full payloads as *distinct objects* (what
        deterministic re-mining of one publication produces) collapse
        to one verification — with verdicts bit-identical to scalar
        calls; a corrupted twin (distinct bytes) never rides the
        honest verdict, and duplicated corrupt evidence is rejected
        everywhere it appears."""
        j = small_collatz()
        wl = JashFullWorkload()
        p1 = full_payload(j)
        fr = p1.full
        twin = dataclasses.replace(
            p1, full=dataclasses.replace(fr, args=fr.args.copy(),
                                         results=fr.results.copy()))
        bad = corrupt_results(p1)
        bad_twin = corrupt_results(twin)
        seg = [p1, twin, bad, twin, bad_twin]
        assert wl.verify_batch(seg) == [wl.verify(p) for p in seg] \
            == [True, True, False, True, False]

    def test_dedup_requires_same_fn(self):
        """``source_id()`` hashes only name+meta, so a payload pairing
        honest evidence with a *different function* under the same id
        must run its own quorum re-execution — never ride the honest
        payload's verdict through the content dedup."""
        j = mix_jash(6, 8)
        wl = JashFullWorkload()
        honest = full_payload(j)

        def other_fn(a):
            return a * jnp.uint32(3)

        impostor_jash = Jash(j.name, other_fn, j.meta,
                             example_args=j.example_args)
        assert impostor_jash.source_id() == j.source_id()
        impostor = dataclasses.replace(honest, jash=impostor_jash)
        assert wl.verify_batch([honest, impostor]) == \
            [wl.verify(honest), wl.verify(impostor)] == [True, False]

    def test_precleared_must_align(self):
        workloads, payloads = self._segment()
        with pytest.raises(ValueError, match="align"):
            verify_chain_batched(workloads, payloads, precleared=[True])


# ---------------------------------------------------------------------------
# node layer: audit_chain, fork-point snapshots, verify cache
# ---------------------------------------------------------------------------


def mixed_net(**node_kwargs) -> Network:
    net = Network.create(2, classic_arg_bits=6, **node_kwargs)
    net.nodes[0].submit(small_collatz())
    net.nodes[1].submit(small_collatz(max_steps=32))
    net.run(4, ["full", "optimal", None, None])
    return net


class TestAuditChain:
    def test_audit_chain_equals_per_block_audits(self):
        net = mixed_net()
        for node in net.nodes:
            assert node.audit_chain() == \
                all(node.audit(h) for h in range(node.ledger.height))
            assert node.audit_chain()

    def test_audit_chain_detects_evidence_swap(self):
        """Tampered full-mode evidence under an untouched header: the
        committed root still matches the header, so rejection must come
        from the batched independent root recompute."""
        net = mixed_net()
        node = net.nodes[0]
        assert node._payloads[0].full is not None      # height 0 is full
        node._payloads[0] = corrupt_results(node._payloads[0])
        assert not node.audit_chain()
        assert not node.audit(0)                       # parity with scalar

    def test_audit_chain_out_of_range_raises(self):
        net = mixed_net()
        from repro.chain.workload import ChainError
        with pytest.raises(ChainError, match="no block"):
            net.nodes[0].audit_chain(heights=[99])


class TestForkPointSnapshots:
    @pytest.mark.parametrize("interval", [0, 1, 2, 8])
    def test_mixed_fork_replay_identical_across_snapshot_policies(
            self, interval):
        """The test_network_edges mixed-workload fork scenario, replayed
        under every snapshot policy (0 = the genesis-replay reference):
        same adoption decision, same tips, same credit books."""
        net = Network.create(
            2, node_factory=lambda i: Node(node_id=i, classic_arg_bits=6,
                                           snapshot_interval=interval))
        n0, n1 = net.nodes
        n0.submit(small_collatz())
        n0.mine_block("full")
        n0.mine_block()
        n1.mine_block()
        n1.submit(small_collatz(max_steps=32))
        n1.mine_block("optimal")
        tip = n1.mine_block()
        res = net.broadcast(1, tip.record.to_block(), tip)
        assert res.accepted_by == [1, 0]
        assert net.converged()
        assert [b.mode for b in n0.ledger.blocks] == \
            ["classic", "optimal", "classic"]
        books = {tuple(sorted(n.book.balances.items())) for n in net.nodes}
        assert len(books) == 1
        assert all(n.audit_chain() for n in net.nodes)
        # the adopted chain keeps extending and has_block's index is
        # consistent after the reorg
        res = net.mine(0)
        assert not res.rejected_by and net.heights == [4, 4]
        for node in net.nodes:
            assert all(node.has_block(b.block_hash)
                       for b in node.ledger.blocks)

    def test_deep_fork_beyond_ring_falls_back_to_genesis(self):
        """A reorg whose fork point predates every ringed checkpoint
        must still adopt correctly (restart from genesis)."""
        a = Node(node_id=0, classic_arg_bits=6, snapshot_interval=1,
                 snapshot_ring=2)
        b = Node(node_id=1, classic_arg_bits=6)
        for _ in range(6):
            a.mine_block()
        for _ in range(7):
            b.mine_block()
        # a's newest checkpoints (heights 5, 6) are past the fork point 0
        assert a.consider_chain(b.ledger.blocks, b.chain_payloads())
        assert a.ledger.tip_hash == b.ledger.tip_hash
        assert sorted(a.book.balances.items()) == \
            sorted(b.book.balances.items())
        assert a.audit_chain()

    def test_rejected_candidate_leaves_node_untouched(self):
        net = mixed_net()
        victim = Node(node_id=9, classic_arg_bits=6)
        victim.mine_block()
        pre_tip = victim.ledger.tip_hash
        pre_book = dict(victim.book.balances)
        donor = net.nodes[0]
        payloads = donor.chain_payloads()
        payloads[2] = dataclasses.replace(payloads[2], best_res="00" * 4)
        assert not victim.consider_chain(donor.ledger.blocks, payloads)
        assert victim.ledger.tip_hash == pre_tip
        assert victim.book.balances == pre_book

    def test_snapshot_params_validated(self):
        with pytest.raises(ValueError, match="snapshot_interval"):
            Node(snapshot_interval=-1)
        with pytest.raises(ValueError, match="snapshot_ring"):
            Node(snapshot_ring=-1)


class TestStatefulSnapshotRing:
    """Checkpoints taken while adopting a chain that contains training
    blocks: batched verification replays the trainer to the *tail end*
    before the commit loop runs, so per-commit checkpoints would pair
    intermediate heights with end-of-chain trainer state.  Fork choice
    must ring only tip-consistent checkpoints, or a later reorg through
    a mid-tail fork point restores a too-advanced trainer and rejects a
    valid longer chain."""

    @staticmethod
    def _training_workload(seed: int = 7):
        from repro.chain import TrainingWorkload
        from repro.configs import get_config, reduced
        from repro.configs.base import InputShape
        from repro.core.pow_train import PoUWTrainer
        from repro.train.steps import TrainHparams
        cfg = reduced(get_config("qwen3-0.6b"))
        shape = InputShape("t", 32, 4, "train")
        return TrainingWorkload(
            lambda: PoUWTrainer(cfg, shape,
                                hp=TrainHparams(peak_lr=1e-3,
                                                warmup_steps=2,
                                                total_steps=16),
                                mode="full", n_miners=2, seed=seed))

    def _node(self, node_id, **kw):
        return Node(node_id=node_id, classic_arg_bits=6,
                    workloads={"training": self._training_workload()},
                    **kw)

    def test_reorg_through_mid_tail_checkpoint_with_training(self):
        donor1 = self._node(1, snapshot_interval=0)
        donor1.mine_block()                       # 0 classic
        donor1.mine_block("training")             # 1 training
        prefix_blocks = list(donor1.ledger.blocks[:2])
        prefix_payloads = donor1.chain_payloads()[:2]
        donor1.mine_block("training")             # 2 training
        donor1.mine_block()                       # 3 classic

        donor2 = self._node(2, snapshot_interval=0)
        assert donor2.consider_chain(prefix_blocks, prefix_payloads)
        donor2.mine_block()                       # 2 classic  (forks)
        donor2.mine_block("training")             # 3 training
        donor2.mine_block()                       # 4 classic

        victim = self._node(0, snapshot_interval=1, snapshot_ring=8)
        reference = self._node(3, snapshot_interval=0)
        for node in (victim, reference):
            assert node.consider_chain(donor1.ledger.blocks,
                                       donor1.chain_payloads())
            # fork point (height 2) predates the adopted tail's end, so
            # any checkpoint at heights 1..3 must hold the trainer state
            # of *that* height, not the tail end's
            assert node.consider_chain(donor2.ledger.blocks,
                                       donor2.chain_payloads())
        assert victim.ledger.tip_hash == reference.ledger.tip_hash \
            == donor2.ledger.tip_hash
        assert sorted(victim.book.balances.items()) == \
            sorted(reference.book.balances.items())
        # the adopted chain keeps extending and re-audits cleanly
        victim.mine_block("training")
        assert victim.audit_chain()

    def test_checkpoint_survives_restore_then_advance(self):
        """A ringed checkpoint that fork choice restores and then
        trains past must be restorable *again* unchanged: the live
        trainer may never alias the checkpoint's stored containers,
        or the second reorg through the same fork point replays from
        corrupted state and rejects a valid longer chain."""
        victim = self._node(0, snapshot_interval=1, snapshot_ring=8)
        reference = self._node(3, snapshot_interval=0)
        for node in (victim, reference):
            node.mine_block()                 # 0 classic
            node.mine_block("training")       # 1 training
            node.mine_block()                 # 2 classic
        prefix_blocks = list(victim.ledger.blocks[:2])
        prefix_payloads = victim.chain_payloads()[:2]

        donor_a = self._node(1, snapshot_interval=0)
        assert donor_a.consider_chain(prefix_blocks, prefix_payloads)
        donor_a.mine_block("training")        # 2 training  (forks)
        donor_a.mine_block()                  # 3 classic
        donor_b = self._node(2, snapshot_interval=0)
        assert donor_b.consider_chain(prefix_blocks, prefix_payloads)
        donor_b.mine_block()                  # 2 classic   (forks)
        donor_b.mine_block("training")        # 3 training
        donor_b.mine_block()                  # 4 classic

        for node in (victim, reference):
            # first reorg restores the height-2 checkpoint and replays
            # a training tail on top of it (victim only; reference
            # replays from genesis)
            assert node.consider_chain(donor_a.ledger.blocks,
                                       donor_a.chain_payloads())
            # second reorg through the SAME fork point restores that
            # checkpoint again — it must still hold height-2 state
            assert node.consider_chain(donor_b.ledger.blocks,
                                       donor_b.chain_payloads())
        assert victim.ledger.tip_hash == reference.ledger.tip_hash \
            == donor_b.ledger.tip_hash
        assert sorted(victim.book.balances.items()) == \
            sorted(reference.book.balances.items())
        victim.mine_block("training")
        assert victim.audit_chain()


class TestVerifyCache:
    def test_network_domain_verifies_each_block_once(self):
        net = Network.create(3, classic_arg_bits=6)
        net.run(3)
        assert net.converged()
        cache = net.verify_cache
        assert cache is not None and len(cache) == 3
        # miner self-verify seeds the cache; the other 2 peers hit it
        assert cache.hits == 3 * 2

    def test_tampered_copy_misses_cache_and_is_rejected(self):
        """Identity keying: a payload copy with honest committed fields
        but tampered evidence must not ride an honest cache entry."""
        net = Network.create(2, classic_arg_bits=6)
        res = net.mine(0)
        blk = res.receipt.record.to_block()
        evil = dataclasses.replace(res.receipt.payload, best_res="00" * 4)
        victim = Node(node_id=7, classic_arg_bits=6)
        victim.verify_cache = net.verify_cache
        assert not victim.receive(blk, evil, origin=0)
        assert victim.ledger.height == 0
        # the honest object (already cached) is accepted via the cache
        hits_before = net.verify_cache.hits
        assert victim.receive(blk, res.receipt.payload, origin=0)
        assert net.verify_cache.hits == hits_before + 1

    def test_opt_out_node_never_enrolled(self):
        net = Network.create(
            2, node_factory=lambda i: Node(
                node_id=i, classic_arg_bits=6,
                use_verify_cache=(i == 0)))
        assert net.nodes[0].verify_cache is net.verify_cache
        assert net.nodes[1].verify_cache is None
        net.run(2)
        assert net.converged()

    def test_sim_reports_identical_with_and_without_cache(self):
        """The cache changes who verifies, never what is decided: the
        bit-reproducible SimReport is identical either way."""
        with_cache = throughput_scenario(4, 6, seed=3).run()
        without = throughput_scenario(4, 6, seed=3,
                                      shared_verify_cache=False).run()
        assert with_cache.to_json() == without.to_json()
        assert with_cache.converged

    def test_canonical_scenarios_unchanged_by_cache(self):
        """Partition/adversarial scenarios still converge with the
        shared domain enabled (the default) — the cache must not leak
        acceptance across partitions or from adversarial payloads."""
        assert partitioned_scenario(seed=5).verify_cache is not None
        r_cache = partitioned_scenario(seed=5).run()
        assert r_cache.converged and r_cache.credit_divergence == 0.0
        r_adv = adversarial_scenario(seed=1).run()
        assert r_adv.converged and r_adv.credit_divergence == 0.0

    def test_cache_bounded_fifo(self):
        """Entries pin whole payloads, so the cache is bounded: oldest
        out first, and an evicted block just re-verifies on next
        receipt."""
        from repro.chain import VerifyCache
        a, b, c = object(), object(), object()
        cache = VerifyCache(maxsize=2)
        cache.add("a", a)
        cache.add("b", b)
        assert cache.check("a", a)
        cache.add("c", c)                  # evicts "a"
        assert len(cache) == 2
        assert not cache.check("a", a) and cache.check("c", c)
        with pytest.raises(ValueError, match="maxsize"):
            VerifyCache(maxsize=0)

    def test_adversary_nodes_not_enrolled(self):
        sim = adversarial_scenario(n_honest=2, seed=0)
        for nid, node in sim._nodes.items():
            if nid in sim._adversaries:
                assert node.verify_cache is None
            else:
                assert node.verify_cache is sim.verify_cache
