"""Wire-protocol tier-1 tests: message codec round-trips, Ed25519
identity (RFC 8032 vectors + forgery), and the adversarial decoding
fuzz sweep — every truncation and every single-bit flip of every
message type must be rejected without an exception, and a stream peer
must survive corrupt frames and keep decoding the good ones."""
import binascii
import dataclasses
import hashlib

import pytest

from repro.chain.net.identity import (KeyRing, PeerIdentity, SignedAnnounce,
                                      ed25519_public_key, ed25519_sign,
                                      ed25519_verify, make_addr,
                                      make_announce, make_identities)
from repro.chain.net.messages import (MAX_ADDRS, MAX_BODY, PROTOCOL_VERSION,
                                      WIRE_MAGIC, Addr, Announce, Bodies,
                                      FrameBuffer, GetBodies, GetHeaders,
                                      Hello, Ping, Pong, Tip,
                                      decode_message, encode_message)
from repro.chain.net.peerbook import PeerBook
from repro.chain.workload import ChainError

# real signed addrs so the ADDR / addr-bearing HELLO specimens survive
# their own decoder (which enforces structural sanity)
_ADDR_IDS, _ADDR_RING = make_identities(3, seed=9)
_ADDR1 = make_addr(_ADDR_IDS[1], "node-1.example", 9101)
_ADDR2 = make_addr(_ADDR_IDS[2], "10.0.0.2", 9102)

# one specimen of every message type, with representative field shapes
_SPECIMENS = [
    Hello(version=PROTOCOL_VERSION, node_id=3, pubkey=b"\x11" * 32,
          height=17),
    Hello(version=PROTOCOL_VERSION, node_id=1, pubkey=_ADDR_IDS[1].pubkey,
          height=4, addr=_ADDR1),
    Announce(header=b"h" * 60, checksum=b"c" * 16, origin=2,
             pubkey=b"\x22" * 32, signature=b"\x33" * 64, body=None),
    Announce(header=b"h" * 60, checksum=b"c" * 16, origin=-1,
             pubkey=b"\x22" * 32, signature=b"\x33" * 64,
             body=b"full body bytes"),
    GetHeaders(from_height=0),
    Tip(start=0, entries=((b"hdr0", b"k" * 16), (b"hdr1", b"\x00" * 16))),
    GetBodies(checksums=(b"a" * 16, b"b" * 16)),
    Bodies(bodies=(b"payload one", b"payload two" * 40)),
    Addr(addrs=(_ADDR1, _ADDR2)),
    Hello(version=PROTOCOL_VERSION, node_id=2, pubkey=_ADDR_IDS[2].pubkey,
          height=9, addr=_ADDR2, observed=("203.0.113.9", 4040)),
    Ping(nonce=0),
    Ping(nonce=2 ** 64 - 1),
    Pong(nonce=0xDEADBEEF),
]


@pytest.mark.parametrize("msg", _SPECIMENS,
                         ids=lambda m: type(m).__name__)
def test_round_trip(msg):
    frame = encode_message(msg)
    assert frame.startswith(WIRE_MAGIC)
    assert decode_message(frame) == msg


def test_decode_rejects_frame_with_trailing_garbage():
    frame = encode_message(_SPECIMENS[0])
    assert decode_message(frame + b"x") is None
    assert decode_message(b"x" + frame) is None


def test_decode_rejects_wrong_magic_and_oversize():
    frame = bytearray(encode_message(_SPECIMENS[0]))
    frame[0] ^= 0xFF
    assert decode_message(bytes(frame)) is None
    big = WIRE_MAGIC + b"\x01" + (MAX_BODY + 1).to_bytes(4, "little")
    assert decode_message(big + b"\x00" * 64) is None


# -- the adversarial sweep (satellite: fuzz every byte position) ----------

@pytest.mark.parametrize("msg", _SPECIMENS,
                         ids=lambda m: type(m).__name__)
def test_truncation_sweep_never_raises_never_accepts(msg):
    """Every proper prefix of every frame decodes to None — a torn
    frame can be neither accepted nor allowed to raise."""
    frame = encode_message(msg)
    for cut in range(len(frame)):
        assert decode_message(frame[:cut]) is None, cut


@pytest.mark.parametrize("msg", _SPECIMENS,
                         ids=lambda m: type(m).__name__)
def test_bitflip_sweep_never_raises_never_accepts(msg):
    """Flip one bit at every byte position: the checksum covers the
    type byte and body, the magic covers itself, the length must match
    exactly — so no single-bit corruption may survive decoding."""
    frame = encode_message(msg)
    for pos in range(len(frame)):
        corrupt = bytearray(frame)
        corrupt[pos] ^= 1 << (pos % 8)
        got = decode_message(bytes(corrupt))
        assert got is None or got == msg  # flips in ignored bits: none
        assert got is None, f"bit flip at byte {pos} accepted"


def test_addr_fuzz_never_enters_peerbook():
    """Satellite: no corruption of an ADDR frame may land an addr in a
    PeerBook.  Byte-level corruption dies in the decoder (checksum /
    structural sanity); decodable-but-tampered records die at
    ``PeerAddr.verify`` inside ``PeerBook.add``."""
    book = PeerBook(self_id=0, keyring=_ADDR_RING)
    frame = encode_message(Addr(addrs=(_ADDR1, _ADDR2)))
    for pos in range(len(frame)):
        corrupt = bytearray(frame)
        corrupt[pos] ^= 1 << (pos % 8)
        got = decode_message(bytes(corrupt))
        assert got is None, f"bit flip at byte {pos} decoded"
        for cut in range(0, len(frame), 3):
            assert decode_message(frame[:cut]) is None
    # a re-signed-field tamper decodes fine (well-formed) but the
    # signature no longer covers the endpoint: the book must refuse it
    moved = dataclasses.replace(_ADDR1, port=_ADDR1.port + 1)
    wire = decode_message(encode_message(Addr(addrs=(moved,))))
    assert wire is not None and wire.addrs[0] == moved
    assert not book.add(wire.addrs[0])
    claimed = dataclasses.replace(_ADDR1, node_id=2)   # identity theft
    wire = decode_message(encode_message(Addr(addrs=(claimed,))))
    assert wire is not None
    assert not book.add(wire.addrs[0])
    assert len(book) == 0 and book.rejected == 2


def test_addr_respects_per_message_cap():
    """> MAX_ADDRS entries: refused at encode, rejected at decode."""
    flood = Addr(addrs=(_ADDR1,) * (MAX_ADDRS + 1))
    with pytest.raises(ChainError):
        encode_message(flood)
    # hand-build the oversize frame the encoder refuses to produce
    from repro.chain.net import messages as M
    from repro.chain.store import _W
    w = _W()
    w.u32(MAX_ADDRS + 1)
    for _ in range(MAX_ADDRS + 1):
        M._enc_peer_addr(w, _ADDR1)
    body = bytes(w.buf)
    frame = (WIRE_MAGIC + bytes([M.MSG_ADDR])
             + len(body).to_bytes(4, "little") + body
             + hashlib.sha256(bytes([M.MSG_ADDR]) + body).digest()[:16])
    assert decode_message(frame) is None


def test_hello_without_addr_still_decodes():
    """The addr payload is optional: a bare HELLO (the PR-7 shape plus
    version bump) round-trips with ``addr=None``."""
    m = decode_message(encode_message(_SPECIMENS[0]))
    assert m is not None and m.addr is None and m.observed is None


def test_hello_malformed_observed_endpoint_rejected():
    """An observed endpoint must satisfy the same structural sanity as
    a PeerAddr endpoint: port 0, empty/oversized/non-ASCII hosts all
    kill the whole frame in the decoder — a peer cannot be talked into
    adopting garbage as its public address."""
    for bad in (("h", 0), ("h", 65536), ("", 80),
                ("x" * 256, 80), ("h\x00st", 80), ("h st", 80)):
        m = Hello(version=PROTOCOL_VERSION, node_id=1,
                  pubkey=b"\x11" * 32, height=2, observed=bad)
        assert decode_message(encode_message(m)) is None, bad


def test_ping_pong_nonce_range_round_trip():
    """Keepalive nonces are unsigned 64-bit on the wire — the u64
    boundary values survive, and a PING never equals the PONG echoing
    the same nonce (distinct message types)."""
    for nonce in (0, 1, 2 ** 32, 2 ** 64 - 1):
        ping, pong = Ping(nonce=nonce), Pong(nonce=nonce)
        assert decode_message(encode_message(ping)) == ping
        assert decode_message(encode_message(pong)) == pong
        assert encode_message(ping) != encode_message(pong)


def test_framebuffer_survives_corruption_and_resyncs():
    """A stream carrying good frame / corrupt frame / good frame must
    yield both good frames; the corrupt one is quarantined."""
    good1 = encode_message(_SPECIMENS[0])
    good2 = encode_message(_SPECIMENS[3])
    corrupt = bytearray(encode_message(_SPECIMENS[5]))
    corrupt[len(corrupt) // 2] ^= 0x40
    fb = FrameBuffer()
    out = []
    stream = good1 + bytes(corrupt) + good2
    for i in range(0, len(stream), 7):      # ragged chunk boundaries
        out.extend(fb.feed(stream[i:i + 7]))
    out.extend(fb.feed(b"", eof=True))
    assert out == [_SPECIMENS[0], _SPECIMENS[3]]
    assert fb.quarantined >= 1
    assert fb.pending() == 0


def test_framebuffer_interframe_garbage_and_partial_magic_tail():
    fb = FrameBuffer()
    good = encode_message(_SPECIMENS[0])
    out = list(fb.feed(b"\xde\xad\xbe\xef" + good))
    assert out == [_SPECIMENS[0]]
    # a tail that is a proper prefix of the magic must just wait...
    assert fb.feed(WIRE_MAGIC[:2]) == []
    # ...and must not wedge the buffer at EOF
    assert fb.feed(b"", eof=True) == []
    assert fb.pending() == 0


# -- identity ------------------------------------------------------------

def test_ed25519_rfc8032_vectors():
    # RFC 8032 §7.1 TEST 1 (empty message) and TEST 2 (one byte)
    seed1 = binascii.unhexlify(
        "9d61b19deffd5a60ba844af492ec2cc44449c5697b326919703bac031cae7f60")
    pub1 = binascii.unhexlify(
        "d75a980182b10ab7d54bfed3c964073a0ee172f3daa62325af021a68f707511a")
    sig1 = binascii.unhexlify(
        "e5564300c360ac729086e2cc806e828a84877f1eb8e5d974d873e06522490155"
        "5fb8821590a33bacc61e39701cf9b46bd25bf5f0595bbe24655141438e7a100b")
    assert ed25519_public_key(seed1) == pub1
    assert ed25519_sign(seed1, b"") == sig1
    assert ed25519_verify(pub1, b"", sig1)

    seed2 = binascii.unhexlify(
        "4ccd089b28ff96da9db6c346ec114e0f5b8a319f35aba624da8cf6ed4fb8a6fb")
    pub2 = binascii.unhexlify(
        "3d4017c3e843895a92b70aa74d1b7ebc9c982ccf2ec4968cc0cd55f12af4660c")
    sig2 = binascii.unhexlify(
        "92a009a9f0d4cab8720e820b5f642540a2b27b5416503f8fb3762223ebdb69da"
        "085ac1e43e15996e458f3613d0f11d8c387b2eaeb4302aeeb00d291612bb0c00")
    assert ed25519_sign(seed2, b"\x72") == sig2
    assert ed25519_verify(pub2, b"\x72", sig2)


def test_ed25519_verify_never_raises_on_junk():
    assert not ed25519_verify(b"\x00" * 32, b"m", b"\x00" * 64)
    assert not ed25519_verify(b"short", b"m", b"\x00" * 64)
    assert not ed25519_verify(b"\xff" * 32, b"m", b"junk")


def test_identity_determinism_and_keyring():
    ids, ring = make_identities(3, seed=5)
    ids2, _ = make_identities(3, seed=5)
    assert ids[0].pubkey == ids2[0].pubkey
    assert ids[0].pubkey != ids[1].pubkey
    assert all(i in ring for i in range(3))
    assert ring.pubkey_of(1) == ids[1].pubkey
    # re-registering a different key for the same node id must fail
    other = PeerIdentity.generate(1)
    with pytest.raises(ValueError):
        ring.register(1, other.pubkey)


def test_signed_announce_binds_origin(two_block_node):
    node, receipt = two_block_node
    ids, ring = make_identities(2)
    block = receipt.record.to_block()
    sa = make_announce(ids[0], block, receipt.payload)
    assert sa.verify_origin(ring)
    assert sa.verify(ring, block, receipt.payload)
    # signature from identity 1 claiming origin 0: forged
    forged = SignedAnnounce(header=sa.header, checksum=sa.checksum,
                            origin=sa.origin, pubkey=ids[1].pubkey,
                            signature=ed25519_sign(
                                ids[1].seed, b"whatever"),
                            )
    assert not forged.verify_origin(ring)
    # bit-flipped signature
    bad_sig = SignedAnnounce(header=sa.header, checksum=sa.checksum,
                             origin=sa.origin, pubkey=sa.pubkey,
                             signature=bytes([sa.signature[0] ^ 1])
                             + sa.signature[1:])
    assert not bad_sig.verify_origin(ring)


@pytest.fixture
def two_block_node():
    from repro.chain.node import Node
    node = Node(node_id=0, classic_arg_bits=6)
    receipt = node.mine_block()
    return node, receipt


def test_payload_checksum_matches_wire(two_block_node):
    from repro.chain.store import encode_payload, payload_checksum
    _, receipt = two_block_node
    body = encode_payload(receipt.payload)
    assert payload_checksum(receipt.payload) == \
        hashlib.sha256(body).digest()[:16]
