"""The application workload suite (DESIGN §11): SAT, GAN inversion, and
docking as first-class chain payloads.

The contracts under test: heterogeneous (mixed-family) networks
converge with bit-identical books; the SAT certificate path is
consensus-safe (forged witnesses, grafted/stripped certificates, and
lazy refutations all reject); docking's data-bundle checksum is part of
block validity; the GAN grid state rolls back through reorgs exactly
like trainer state (snapshot-policy invariant); and batched
verification equals the per-block loop on every family.
"""
import dataclasses

import numpy as np
import pytest

from repro.chain import Network, Node
from repro.chain.sim import heterogeneous_scenario
from repro.chain.workload import certificate_digest, verify_chain_batched
from repro.chain.workloads import (DockingBundle, DockingWorkload,
                                   GanInversionWorkload, SatWorkload,
                                   WORKLOAD_FAMILIES, default_suite)

SMALL = dict(sat={"n_vars": 8, "n_clauses": 32},
             gan={"grid_bits": 6},
             docking={"n_r": 8, "n_p": 8})


def suite_node(i: int, seed: int = 7, **node_kwargs) -> Node:
    return Node(node_id=i, classic_arg_bits=6,
                workloads=default_suite(seed=seed, **SMALL), **node_kwargs)


def mine_schedule(net: Network, schedule) -> list:
    out = []
    for b, family in enumerate(schedule):
        out.append(net.mine(b % len(net.nodes), family))
    return out


# ---------------------------------------------------------------------------
# heterogeneous networks
# ---------------------------------------------------------------------------


class TestMixedFamilyNetwork:
    SCHEDULE = ("sat", "gan", "docking", "classic", "sat", "gan", "docking")

    def test_three_node_convergence(self):
        net = Network.create(3, node_factory=suite_node)
        for res in mine_schedule(net, self.SCHEDULE):
            assert not res.rejected_by
        assert net.converged()
        books = {tuple(sorted(n.book.balances.items())) for n in net.nodes}
        assert len(books) == 1
        # every node's GAN grid advanced through the same rounds
        digests = {n.workloads["gan"].state_digest() for n in net.nodes}
        assert len(digests) == 1

    def test_batched_equals_per_block_loop(self):
        """The acceptance contract: per-block audit loop ==
        ``audit_chain`` (verify_chain_batched) on a mixed-family
        chain, and both accept."""
        net = Network.create(2, node_factory=suite_node)
        mine_schedule(net, self.SCHEDULE)
        for node in net.nodes:
            per_block = all(node.audit(h)
                            for h in range(node.ledger.height))
            assert per_block and node.audit_chain()
        # and directly at the workload layer, on a fresh verifier
        fresh = suite_node(9)
        payloads = net.nodes[0].chain_payloads()
        assert verify_chain_batched(fresh.workloads, payloads)

    def test_registry_key_must_match_workload_name(self):
        with pytest.raises(ValueError, match="registry key must match"):
            Node(workloads={"mislabeled": SatWorkload()})

    def test_register_workload_after_construction(self):
        node = Node(node_id=0, classic_arg_bits=6)
        node.register_workload(SatWorkload(**SMALL["sat"]))
        assert node.mine_block("sat").record.workload == "sat"
        with pytest.raises(ValueError, match="already registered"):
            node.register_workload(SatWorkload())

    def test_families_registry_is_consistent(self):
        for name, cls in WORKLOAD_FAMILIES.items():
            assert cls.name == name


# ---------------------------------------------------------------------------
# SAT certificates
# ---------------------------------------------------------------------------


def _mine_sat(node: Node, want_cert: bool):
    """Mine sat blocks until one with (or without) a certificate shows
    up — instance k = height, so the verdict varies per block."""
    for _ in range(40):
        receipt = node.mine_block("sat")
        if (receipt.payload.certificate is not None) == want_cert:
            return receipt
    raise AssertionError(f"no {'SAT' if want_cert else 'UNSAT'} instance "
                         "found in 40 blocks — enlarge the search")


class TestSatCertificates:
    def test_forged_witness_rejected(self):
        """A certificate whose digest matches the header but whose
        assignment does not satisfy the formula must reject — and
        cheaply (the O(clauses) path)."""
        miner, peer = suite_node(0), suite_node(1)
        receipt = _mine_sat(miner, want_cert=True)
        p = receipt.payload
        witness = int(np.frombuffer(p.certificate, "<u4")[0])
        forged_arg = (witness + 1) % (1 << SMALL["sat"]["n_vars"])
        cert = np.uint32(forged_arg).tobytes()
        forged = dataclasses.replace(
            p, certificate=cert, state_digest=certificate_digest(cert),
            winner=(p.origin * 65536) + forged_arg % p.n_miners)
        sat = peer.workloads["sat"]
        assert sat.verify(p)
        assert not sat.verify(forged)
        assert sat.verify_batch([p, forged]) == [True, False]

    def test_stripped_or_grafted_certificate_rejected(self):
        """The digest binding works both ways: stripping a certificate
        (turning SAT into a bogus refutation) and grafting one onto an
        UNSAT block both fail."""
        miner, peer = suite_node(0), suite_node(1)
        sat_p = _mine_sat(miner, want_cert=True).payload
        sat = peer.workloads["sat"]
        # strip: digest still signs the certificate -> header mismatch
        stripped = dataclasses.replace(sat_p, certificate=None)
        assert not sat.verify(stripped)
        # strip AND rewrite digest: now a refutation claim whose own
        # evidence table contains a satisfying row -> rejected
        lazy = dataclasses.replace(sat_p, certificate=None,
                                   state_digest="", winner=None)
        assert not sat.verify(lazy)
        unsat_p = _mine_sat(suite_node(2), want_cert=False).payload
        cert = np.uint32(0).tobytes()
        grafted = dataclasses.replace(
            unsat_p, certificate=cert,
            state_digest=certificate_digest(cert),
            winner=unsat_p.origin * 65536)
        assert not sat.verify(grafted)

    def test_corrupted_refutation_table_rejected(self):
        miner, peer = suite_node(0), suite_node(1)
        p = _mine_sat(miner, want_cert=False).payload
        bad = p.full.results.copy()
        bad[3, 0] ^= 1
        forged = dataclasses.replace(
            p, full=dataclasses.replace(p.full, results=bad))
        sat = peer.workloads["sat"]
        assert sat.verify(p) and not sat.verify(forged)
        assert sat.verify_batch([forged, p]) == [False, True]

    def test_forged_certificate_rejected_on_network_receive(self):
        net = Network.create(2, node_factory=suite_node)
        miner = net.nodes[0]
        receipt = _mine_sat(miner, want_cert=True)
        # miner already committed it locally; hand-deliver a forged copy
        cert = np.uint32((int.from_bytes(receipt.payload.certificate,
                                         "little") + 1) % 256).tobytes()
        forged = dataclasses.replace(
            receipt.payload, certificate=cert,
            state_digest=certificate_digest(cert))
        blk = dataclasses.replace(receipt.record.to_block(),
                                  state_digest=forged.state_digest)
        assert not net.nodes[1].receive(blk, forged, origin=0)


# ---------------------------------------------------------------------------
# docking data binding
# ---------------------------------------------------------------------------


class TestDockingBundle:
    def test_tampered_bundle_rejects_honest_block(self):
        net = Network.create(2, node_factory=suite_node)
        res = net.mine(0, "docking")
        assert not res.rejected_by
        honest = net.nodes[0].workloads["docking"].bundle
        tampered = DockingBundle(receptors=honest.receptors ^ 1,
                                 peptides=honest.peptides)
        bad_peer = Node(node_id=5, workloads={
            "docking": DockingWorkload(bundle=tampered)})
        assert not bad_peer.receive(res.receipt.record.to_block(),
                                    res.receipt.payload, origin=0)

    def test_checksum_is_part_of_jash_id(self):
        a = DockingWorkload(**SMALL["docking"], seed=0)
        b = DockingWorkload(**SMALL["docking"], seed=1)
        assert a._jash.source_id() != b._jash.source_id()

    def test_verify_batch_dedups_repeat_screenings(self):
        """Deterministic re-screening of one bundle is byte-identical
        evidence — a repeated segment batch-verifies identically to
        the scalar loop."""
        miner = suite_node(0)
        payloads = [miner.mine_block("docking").payload for _ in range(3)]
        peer = suite_node(1).workloads["docking"]
        assert peer.verify_batch(payloads) == \
            [peer.verify(p) for p in payloads] == [True] * 3


# ---------------------------------------------------------------------------
# GAN inversion: stateful rollback
# ---------------------------------------------------------------------------


class TestGanRollback:
    @pytest.mark.parametrize("snapshot_interval", [0, 2])
    def test_reorg_rolls_grid_back(self, snapshot_interval):
        """A reorg that drops local GAN rounds must rewind the grid so
        the node can re-mine them on the adopted chain — and the
        outcome is invariant to the fork-choice snapshot policy
        (genesis replay == ringed checkpoints)."""
        a = suite_node(0, snapshot_interval=snapshot_interval)
        b = suite_node(1)
        a.mine_block("gan")
        b_payload = b.mine_block("gan").payload      # identical round 0
        assert a.workloads["gan"].state_digest() == \
            b.workloads["gan"].state_digest()
        a.mine_block("gan")                          # A: rounds 0, 1
        for _ in range(3):                           # B: round 0 + classic
            b.mine_block("classic")
        assert a.workloads["gan"].round == 2
        assert a.consider_chain(b.ledger.blocks, b.chain_payloads())
        # round 1 was reorged away -> grid state rewound to round 1's start
        assert a.workloads["gan"].round == 1
        assert a.workloads["gan"].state_digest() == \
            b.workloads["gan"].state_digest()
        # and the chain keeps extending consistently: A re-mines round 1,
        # B accepts it on receive (bit-identical replay)
        receipt = a.mine_block("gan")
        assert b.receive(receipt.record.to_block(), receipt.payload,
                         origin=0)
        assert b_payload.train_height == 0           # sanity

    def test_failed_candidate_leaves_state_untouched(self):
        a, b = suite_node(0), suite_node(1)
        a.mine_block("gan")
        digest = a.workloads["gan"].state_digest()
        b.mine_block("gan")
        b.mine_block("gan")
        blocks = list(b.ledger.blocks)
        payloads = b.chain_payloads()
        corrupted = [payloads[0],
                     dataclasses.replace(payloads[1], best_arg=-1)]
        assert not a.consider_chain(blocks, corrupted)
        assert a.workloads["gan"].round == 1
        assert a.workloads["gan"].state_digest() == digest

    def test_future_round_rejected(self):
        a, b = suite_node(0), suite_node(1)
        b.mine_block("gan")
        r2 = b.mine_block("gan")                     # round 1 while a is at 0
        assert not a.workloads["gan"].verify(r2.payload)
        assert a.workloads["gan"].round == 0


# ---------------------------------------------------------------------------
# the heterogeneous sim scenario
# ---------------------------------------------------------------------------


class TestHeterogeneousScenario:
    def test_converges_and_is_reproducible(self):
        rep1 = heterogeneous_scenario(seed=0).run()
        assert rep1.converged
        assert rep1.credit_divergence == 0.0
        assert rep1.orphans >= 1                 # the corrupter's blocks
        rep2 = heterogeneous_scenario(seed=0).run()
        assert rep1.to_json() == rep2.to_json()
